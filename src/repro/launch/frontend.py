"""Async streaming front-end over ServeEngine: concurrent clients,
per-request token streams, multi-method serving, double-buffered dispatch.

HLS dataflow intuition (DESIGN.md sec. 11): SILVIA's kernels hit II=1 by
overlapping stages -- while the datapath crunches beat N, the control
logic is already fetching beat N+1.  The serve loop here is the same
two-stage software pipeline, exploiting JAX's asynchronous dispatch: a
decode segment is DISPATCHED (engine.step_begin -- returns device
futures, the host does not block), and while the device crunches the host
runs the serve loop's control work -- publishing segment N-1's freshly
harvested tokens to per-request streams, warming the NEXT admission's
prefix-cache digests (engine.admission_plan), and absorbing client
submits/cancels -- before blocking on the segment (engine.step_finish).
With ``overlap=False`` the same work runs serially after the sync, which
is the baseline benchmarks/serve_latency.py measures the pipeline
against.

Why overlap cannot change a single bit: the host work between begin and
finish never dispatches to the device and never touches decode state --
it reads already-harvested tokens, hashes queued prompts, and mutates
only the queue (submit/cancel).  The dispatch order of device work is
identical with and without overlap, so streamed tokens are byte-identical
to the batch engine's output (tests/test_frontend.py asserts this for
all four families, under chaos, meshes and a warm prefix cache).

Threading model (the saxml enqueue/dequeue-stream pattern): ONE worker
thread owns the engine; asyncio clients talk to it through a command
queue (submit/cancel/stop) and receive tokens through BOUNDED per-stream
asyncio queues fed via ``loop.call_soon_threadsafe``.  A stream whose
consumer stops draining overflows its queue and is cancelled
("stream backlog exceeded") instead of wedging the serve loop; a
consumer that disconnects mid-stream (GeneratorExit) cancels its request,
freeing the slot while keeping the partial tokens in the result.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import queue as _thread_queue
import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.launch import methods
from repro.launch import resilience as res
from repro.launch import scheduler


@dataclasses.dataclass
class _Done:
    """End-of-stream marker carrying the structured result."""
    result: Optional[res.RequestResult]
    error: Optional[BaseException] = None


class AsyncFrontend:
    """Asyncio host loop around a ServeEngine (module docstring).

    Parameters
    ----------
    engine:       the ServeEngine to serve (exclusively owned by the
                  front-end's worker thread between start() and stop()).
    clock:        serving clock; a scheduler.FastForwardClock runs
                  virtual time (tests), the default real Clock serves
                  wall-clock traffic (benchmarks).
    overlap:      True (default) runs the two-stage pipeline; False
                  syncs each segment before doing host work -- the
                  no-overlap baseline.
    stream_queue: per-stream token buffer bound; an undrained stream
                  that overflows it is cancelled, not buffered forever.
    poll_s:       idle wait granularity of the worker loop.
    """

    def __init__(self, engine, *, clock: Optional[scheduler.Clock] = None,
                 overlap: bool = True, stream_queue: int = 256,
                 poll_s: float = 0.02):
        self.engine = engine
        self.clock = clock if clock is not None else scheduler.Clock()
        self.overlap = overlap
        self._qsize = stream_queue
        self._poll_s = poll_s
        self._cmds: "_thread_queue.SimpleQueue" = _thread_queue.SimpleQueue()
        self._rids = itertools.count()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = False
        # worker-thread state
        self._live: dict = {}          # rid -> live Request
        self._fin_idx = 0              # engine.finished cursor
        self._sent: dict = {}          # rid -> tokens already published
        # event-loop state
        self._streams: dict = {}       # rid -> asyncio.Queue
        self._waiters: dict = {}       # rid -> asyncio.Future
        self.stats = {"submitted": 0, "streamed_tokens": 0,
                      "overlapped_segments": 0, "disconnect_cancels": 0,
                      "backlog_cancels": 0, "hidden_host_s": 0.0}

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> "AsyncFrontend":
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="serve-frontend", daemon=True)
        self._thread.start()
        return self

    async def stop(self) -> None:
        """Stop the worker loop (in-flight device work completes; queued
        requests stay queued on the engine)."""
        if self._thread is None:
            return
        self._cmds.put(("stop",))
        await asyncio.get_running_loop().run_in_executor(
            None, self._thread.join)
        self._thread = None

    async def __aenter__(self) -> "AsyncFrontend":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # -- client API ---------------------------------------------------------

    def _new_rid(self, rid: Optional[int]) -> int:
        return next(self._rids) if rid is None else int(rid)

    async def _call(self, req: scheduler.Request) -> res.RequestResult:
        fut = asyncio.get_running_loop().create_future()
        self._waiters[req.rid] = fut
        self.stats["submitted"] += 1
        self._cmds.put(("submit", req))
        try:
            return await fut
        finally:
            self._waiters.pop(req.rid, None)

    async def generate(self, prompt, max_new_tokens: int, *,
                       rid: Optional[int] = None,
                       stop_tokens: Optional[Sequence[int]] = None,
                       features=None,
                       deadline: Optional[float] = None,
                       sampling=None) -> res.RequestResult:
        """Non-streaming generation; resolves to the structured result.
        `sampling` is an optional scheduler.SamplingParams (temperature /
        top-k / top-p / seed); None means greedy."""
        return await self._call(methods.generate_request(
            self._new_rid(rid), prompt, max_new_tokens,
            arrival_time=self.clock.now(), stop_tokens=stop_tokens,
            features=features, deadline=deadline, sampling=sampling))

    async def generate_stream(self, prompt, max_new_tokens: int, *,
                              rid: Optional[int] = None,
                              stop_tokens: Optional[Sequence[int]] = None,
                              features=None,
                              deadline: Optional[float] = None,
                              sampling=None):
        """Async iterator of generated tokens, published per segment as
        they are harvested.  Exiting the iteration early (client
        disconnect) cancels the request: its slot frees mid-stream and
        the tokens streamed so far stay in the CANCELLED result."""
        rid = self._new_rid(rid)
        q: asyncio.Queue = asyncio.Queue(self._qsize)
        self._streams[rid] = q
        self.stats["submitted"] += 1
        req = methods.generate_request(
            rid, prompt, max_new_tokens, arrival_time=self.clock.now(),
            stop_tokens=stop_tokens, features=features, deadline=deadline)
        self._cmds.put(("submit", req))
        done = False
        try:
            while True:
                item = await q.get()
                if isinstance(item, _Done):
                    done = True
                    if item.error is not None:
                        raise item.error
                    return
                yield item
        finally:
            self._streams.pop(rid, None)
            if not done:
                self.stats["disconnect_cancels"] += 1
                self._cmds.put(("cancel", rid, "client disconnected"))

    async def score(self, prompt, completion: Sequence[int], *,
                    rid: Optional[int] = None, features=None,
                    deadline: Optional[float] = None) -> list:
        """Per-token logprobs of `completion` under `prompt` (the score
        method; exact decode-path parity, launch/methods.py)."""
        result = await self._call(methods.score_request(
            self._new_rid(rid), prompt, completion,
            arrival_time=self.clock.now(), features=features,
            deadline=deadline))
        return methods.completion_logprobs(result)

    async def embed(self, prompt, *, rid: Optional[int] = None,
                    features=None,
                    deadline: Optional[float] = None) -> np.ndarray:
        """Pooled final-hidden-state embedding of `prompt`."""
        result = await self._call(methods.embed_request(
            self._new_rid(rid), prompt, arrival_time=self.clock.now(),
            features=features, deadline=deadline))
        return methods.embedding(result)

    async def cancel(self, rid: int, reason: Optional[str] = None) -> None:
        self._cmds.put(("cancel", int(rid), reason or "client cancel"))

    # -- worker loop (owns the engine) --------------------------------------

    def _serve_loop(self) -> None:
        eng, clock = self.engine, self.clock
        while True:
            self._drain_cmds()
            if self._stop_flag:
                return
            pending, progressed = eng.step_begin(clock)
            if pending is not None:
                if self.overlap:
                    # two-stage pipeline: host work runs WHILE the
                    # dispatched segment is in flight.  hidden_host_s is
                    # the measured overlap -- host time that a sync loop
                    # would have added to the dispatch-to-dispatch path.
                    self.stats["overlapped_segments"] += 1
                    t0 = time.monotonic()
                    self._host_stage()
                    self.stats["hidden_host_s"] += time.monotonic() - t0
                    eng.step_finish(pending, clock)
                    self._publish()
                else:
                    eng.step_finish(pending, clock)
                    self._host_stage()
                continue
            self._publish()
            if progressed:
                continue
            self._idle_wait()

    def _host_stage(self) -> None:
        """The control half of the pipeline: publish segment N-1's
        harvested tokens, warm the next admission's prefix digests, and
        absorb client commands -- all host-only (no device dispatch, no
        decode-state mutation), so running it under an in-flight segment
        cannot perturb a bit."""
        self._publish()
        self.engine.admission_plan()
        self._drain_cmds()

    def _idle_wait(self) -> None:
        """Nothing active and nothing admitted: wait for the next queued
        arrival (virtual clocks jump straight to it) or the next client
        command, whichever is first."""
        clock = self.clock
        nxt = self.engine.next_arrival(clock.now())
        if isinstance(clock, scheduler.FastForwardClock):
            if nxt is not None:
                clock.wait_until(nxt)
                return
            timeout = self._poll_s
        else:
            timeout = self._poll_s if nxt is None else \
                max(0.0, min(nxt - clock.now(), self._poll_s))
        try:
            cmd = self._cmds.get(timeout=timeout)
        except _thread_queue.Empty:
            return
        self._handle_cmd(cmd)

    def _drain_cmds(self) -> None:
        while True:
            try:
                cmd = self._cmds.get_nowait()
            except _thread_queue.Empty:
                return
            self._handle_cmd(cmd)

    def _handle_cmd(self, cmd: tuple) -> None:
        if cmd[0] == "stop":
            self._stop_flag = True
        elif cmd[0] == "submit":
            req = cmd[1]
            self._live[req.rid] = req
            try:
                self.engine.submit(req)
            except Exception as e:  # validation error -> the caller
                self._live.pop(req.rid, None)
                self._deliver_error(req.rid, e)
        elif cmd[0] == "cancel":
            _, rid, reason = cmd
            self.engine.cancel(rid, now=self.clock.now(), reason=reason)

    # -- publishing (worker thread -> event loop) ---------------------------

    def _publish(self) -> None:
        """Push per-stream token deltas and completed results.  Deltas
        come from each live Request's append-only token list (recovery
        replays never re-append, so a delta is never re-published), and
        completion is detected from the engine's finished list -- both
        plain host reads, safe to run under an in-flight segment."""
        for rid, req in list(self._live.items()):
            if rid in self._streams:
                sent = self._sent.get(rid, 0)
                toks = req.tokens
                if len(toks) > sent:
                    for t in toks[sent:]:
                        self._push(rid, int(t))
                    self.stats["streamed_tokens"] += len(toks) - sent
                    self._sent[rid] = len(toks)
        fin = self.engine.finished
        while self._fin_idx < len(fin):
            req = fin[self._fin_idx]
            self._fin_idx += 1
            rid = req.rid
            if rid not in self._live:
                continue        # not ours (engine shared with a driver)
            self._live.pop(rid, None)
            sent = self._sent.pop(rid, 0)
            result = self.engine.result(rid)
            if rid in self._streams:
                for t in req.tokens[sent:]:
                    self._push(rid, int(t))
                    self.stats["streamed_tokens"] += 1
                self._push(rid, _Done(result))
            else:
                self._deliver_result(rid, result)

    def _push(self, rid: int, item) -> None:
        loop = self._loop

        def put() -> None:
            q = self._streams.get(rid)
            if q is None:
                return
            try:
                q.put_nowait(item)
            except asyncio.QueueFull:
                # slow consumer: cancel rather than buffer unboundedly
                # or stall every other stream behind this one
                self.stats["backlog_cancels"] += 1
                self._cmds.put(("cancel", rid, "stream backlog exceeded"))

        loop.call_soon_threadsafe(put)

    def _deliver_result(self, rid: int, result) -> None:
        def done() -> None:
            fut = self._waiters.get(rid)
            if fut is not None and not fut.done():
                fut.set_result(result)

        self._loop.call_soon_threadsafe(done)

    def _deliver_error(self, rid: int, exc: BaseException) -> None:
        def fail() -> None:
            fut = self._waiters.get(rid)
            if fut is not None and not fut.done():
                fut.set_exception(exc)
            q = self._streams.get(rid)
            if q is not None:
                try:
                    q.put_nowait(_Done(None, error=exc))
                except asyncio.QueueFull:
                    pass

        self._loop.call_soon_threadsafe(fail)


async def serve_requests(frontend: AsyncFrontend,
                         requests: Sequence[scheduler.Request]) -> dict:
    """Convenience driver: submit pre-built Requests (any method mix)
    concurrently through a running front-end and gather their structured
    results keyed by rid -- what the stream-vs-batch equality tests and
    the latency benchmark build on."""
    async def one(req: scheduler.Request):
        return req.rid, await frontend._call(req)

    pairs = await asyncio.gather(*(one(r) for r in requests))
    return dict(pairs)
