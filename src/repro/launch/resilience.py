"""Serving resilience: admission control, fault injection, recovery.

SILVIA's contract is that an aggressive transformation (packing narrow
ops into one DSP) must be provably behavior-preserving; this repo carries
that bar into serving as bit-exactness (engine == static ``generate()``,
sharded == single-device).  This module supplies the FAILURE half of that
story for `launch/engine.py`, with the same proof obligation: a
fault-injected run must reproduce the fault-free token streams exactly
(DESIGN.md sec. 8).

Four pillars, all integrated into the engine:

* **admission control** -- `ResilienceConfig`: a bounded request queue with
  a load-shedding policy (reject the newcomer, or drop the oldest queued
  request to make room) and per-request deadlines/TTL.  Expired queued
  requests never dispatch; expired in-flight requests are cancelled via
  slot eviction between segments, keeping their partial tokens.
* **structured outcomes** -- every submitted request ends in exactly one of
  `OK` / `SHED` / `EXPIRED` / `FAILED` (`RequestResult`); dispatch
  exceptions recover instead of crashing the engine loop.
* **fault injection** -- `ChaosSchedule` extends
  `distributed.fault.FailureInjector` into the serving dispatch path:
  sites are `(kind, index)` pairs over the engine's monotonically counted
  dispatches (``segment:3``, ``prefill:0``, ``chunk:7``), listed
  explicitly or drawn by a deterministic seeded hash at a given rate.
  `$REPRO_CHAOS` arms every engine in the process (the CI `tier1-chaos`
  job runs the whole engine/sharded suites this way).
* **recovery as replay** -- on any dispatch failure the engine requeues
  in-flight requests WITH their already-emitted tokens; at re-admission it
  re-prefills the ORIGINAL prompt (same prompt bucket, same graphs) and
  replays the emitted tokens through the single-token decode path with
  teacher forcing.  Replay repeats bitwise the ops of the fault-free run
  -- prefill(prompt) then per-token decode -- so recovered streams are
  bit-identical for every family.  Re-prefilling ``prompt + emitted`` in
  one go would NOT be exact for sequential-state families (ssd_forward's
  chunked summation order differs from stepwise ssd_decode; see
  ROADMAP/slot_state.FamilyState.prefill_chunkable), and would also leak
  new prompt-bucket graphs.  Determinism doubles as the proof obligation:
  the engine verifies each replayed token against the recorded stream and
  counts any divergence (`replay_divergence`, asserted zero in tests).

Snapshot/restore (`snapshot_requests` / `restore_requests`) persists the
queue + per-slot request state through `checkpoint/ckpt.py` for rolling
restarts; device state is NOT serialized -- restore re-enters the
recovery path above, which regenerates it bit-exactly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import ckpt
from repro.distributed.fault import FailureInjector, SimulatedFailure

__all__ = [
    "OK", "SHED", "EXPIRED", "FAILED", "CANCELLED", "QUEUED",
    "RequestResult", "ResilienceConfig", "ChaosSchedule",
    "chaos_from_env", "snapshot_requests", "restore_requests",
    "SimulatedFailure",
]

# terminal request outcomes (structured results instead of exceptions)
OK = "ok"              # full stream delivered
SHED = "shed"          # rejected by admission control (bounded queue)
EXPIRED = "expired"    # deadline/TTL passed (queued or in-flight)
FAILED = "failed"      # quarantined (non-finite logits) / retries exhausted
CANCELLED = "cancelled"  # client cancelled (stream disconnect / cancel(rid))
# submit() return value for an accepted request (not a terminal outcome)
QUEUED = "queued"


@dataclasses.dataclass
class RequestResult:
    """Structured terminal outcome of one request (engine.results())."""
    rid: int
    outcome: str                # OK | SHED | EXPIRED | FAILED | CANCELLED
    tokens: List[int]           # possibly partial (EXPIRED/FAILED/CANCELLED)
    error: Optional[str] = None
    retries: int = 0                  # fault recoveries this request rode
    logprobs: Optional[List[float]] = None   # score method: per-token lp
    embedding: Optional[np.ndarray] = None   # embed method: [d_model] f32


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Admission-control policy for a `ServeEngine`.

    max_queue:       queued-request bound; None = unbounded (the
                     pre-resilience behavior).
    shed_policy:     what to do when the queue is full: "reject-new"
                     sheds the incoming request, "drop-oldest" sheds the
                     oldest queued request to admit the newcomer.
    default_ttl_s:   default per-request TTL (deadline = arrival + ttl)
                     applied at submit() when the request carries no
                     explicit deadline; None = no deadline.
    max_recoveries:  per-request cap on fault recoveries; a request that
                     exceeds it is FAILED instead of requeued (bounds the
                     work a persistently failing dispatch can absorb).
    """
    max_queue: Optional[int] = None
    shed_policy: str = "reject-new"
    default_ttl_s: Optional[float] = None
    max_recoveries: int = 8

    def __post_init__(self):
        if self.shed_policy not in ("reject-new", "drop-oldest"):
            raise ValueError(
                f"shed_policy must be 'reject-new' or 'drop-oldest', got "
                f"{self.shed_policy!r}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None)")


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------

def _hash_frac(seed: int, site: str) -> float:
    """Deterministic uniform [0,1) from (seed, site) -- stable across
    processes/hosts, unlike `hash()`."""
    h = hashlib.sha256(f"{seed}|{site}".encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


@dataclasses.dataclass
class ChaosSchedule(FailureInjector):
    """FailureInjector over serving dispatch sites.

    Sites are ``kind:index`` strings over the engine's per-kind dispatch
    counters (kinds: "segment", "prefill", "chunk").  A site fails when
    listed in `fail_at_sites` or when the deterministic hash of
    (seed, site) falls under `rate`; each site fires at most once and
    `max_failures` (if set) caps total injections, so chaos always makes
    forward progress.
    """
    rate: float = 0.0
    seed: int = 0
    max_failures: Optional[int] = None

    # the engine's guarded dispatch kinds (launch/engine.py _guarded);
    # draft/verify are the speculative-decode round's dispatches
    SITE_KINDS = frozenset({"segment", "prefill", "chunk", "embed",
                            "draft", "verify"})

    def should_fail(self, site: str) -> bool:
        if site in self.fail_at_sites:
            return True
        return self.rate > 0 and _hash_frac(self.seed, site) < self.rate

    def check_site(self, site: str) -> None:
        if site in self.failed:
            return
        if self.max_failures is not None \
                and len(self.failed) >= self.max_failures:
            return
        if self.should_fail(site):
            self.failed.add(site)
            raise SimulatedFailure(f"injected serving fault at {site}")

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        """Parse a $REPRO_CHAOS schedule.

        Tokens separated by ',' or ';':  explicit sites ``kind:index``
        (e.g. ``segment:3``), and/or ``rate=F`` / ``seed=N`` / ``max=N``
        for the deterministic random schedule::

            REPRO_CHAOS='segment:1;prefill:0'
            REPRO_CHAOS='rate=0.05,seed=11'
            REPRO_CHAOS='rate=0.2,seed=3,max=4;chunk:2'
        """
        sites: List[str] = []
        rate, seed, max_failures = 0.0, 0, None
        for tok in (t.strip() for part in spec.split(";")
                    for t in part.split(",")):
            if not tok:
                continue
            if "=" in tok:
                k, v = tok.split("=", 1)
                k = k.strip()
                if k == "rate":
                    rate = float(v)
                elif k == "seed":
                    seed = int(v)
                elif k == "max":
                    max_failures = int(v)
                else:
                    raise ValueError(
                        f"REPRO_CHAOS: unknown key {k!r} in {spec!r} "
                        f"(want rate=/seed=/max= or kind:index sites)")
            elif ":" in tok:
                kind, idx = tok.split(":", 1)
                if kind not in cls.SITE_KINDS or not idx.isdigit():
                    raise ValueError(
                        f"REPRO_CHAOS: bad site {tok!r} (want "
                        f"segment:N, prefill:N, chunk:N, embed:N, "
                        f"draft:N or verify:N)")
                sites.append(tok)
            else:
                raise ValueError(f"REPRO_CHAOS: cannot parse token {tok!r}")
        return cls(fail_at_sites=tuple(sites), rate=rate, seed=seed,
                   max_failures=max_failures)


def chaos_from_env() -> Optional[ChaosSchedule]:
    """The process-wide chaos schedule from $REPRO_CHAOS (None if unset).
    Read at engine construction, so the whole engine/sharded test suites
    run under injected faults simply by exporting the variable.  Specs
    containing device-loss arms (``lose@site``/``lose_rate=``...) parse
    into a `distributed.elastic.DeviceLossInjector` -- imported lazily,
    since elastic builds on this module."""
    spec = os.environ.get("REPRO_CHAOS", "").strip()
    if not spec:
        return None
    if "lose" in spec:
        from repro.distributed import elastic
        return elastic.DeviceLossInjector.parse(spec)
    return ChaosSchedule.parse(spec)


# ---------------------------------------------------------------------------
# queue + per-slot request snapshots (rolling restarts)
# ---------------------------------------------------------------------------

def _encode_requests(requests: Sequence[Any]) -> Tuple[list, dict]:
    """(pytree of arrays, JSON-able meta) for checkpoint/ckpt.py.

    Arrays (prompt, emitted tokens, optional encdec features) go in the
    tree; scalars/metadata ride in the checkpoint's extra_meta.  Device
    state is deliberately absent: restore replays (module docstring)."""
    tree, meta = [], []
    for r in requests:
        leaf = {"prompt": np.asarray(r.prompt, np.int32),
                "tokens": np.asarray(r.tokens, np.int32)}
        if r.features is not None:
            leaf["features"] = np.asarray(r.features, np.float32)
        if r.score_tokens is not None:
            leaf["score_tokens"] = np.asarray(r.score_tokens, np.int32)
        tree.append(leaf)
        meta.append({
            "rid": int(r.rid),
            "max_new_tokens": int(r.max_new_tokens),
            "arrival_time": float(r.arrival_time),
            "deadline": None if r.deadline is None else float(r.deadline),
            "stop_tokens": None if r.stop_tokens is None
            else [int(t) for t in r.stop_tokens],
            "retries": int(r.retries),
            "has_features": r.features is not None,
            "method": r.method,
            "has_score_tokens": r.score_tokens is not None,
            # per-request sampling policy (launch/sampling.py): the
            # counter-based keys need only these scalars, so a restored
            # sampled request replays -- and then continues -- its exact
            # stream with no sampler state in the snapshot
            "sampling": None if r.sampling is None else {
                "temperature": float(r.sampling.temperature),
                "top_k": int(r.sampling.top_k),
                "top_p": float(r.sampling.top_p),
                "seed": int(r.sampling.seed),
            },
        })
    return tree, {"requests": meta}


def snapshot_requests(ckpt_dir: str, step: int, requests: Sequence[Any],
                      extra: Optional[dict] = None) -> str:
    """Atomically persist request-level serve state (ckpt.py layout).
    `extra` rides along in the checkpoint meta (the engine stamps its
    current mesh topology here).  Restore IGNORES it by design: request
    state is mesh-free, which is exactly why a snapshot taken on one
    mesh restores onto any other -- replay regenerates device state on
    whatever topology the restoring engine runs."""
    tree, meta = _encode_requests(requests)
    if extra:
        meta = {**extra, **meta}    # "requests" always wins
    return ckpt.save_checkpoint(ckpt_dir, step, tree, extra_meta=meta)


def restore_requests(ckpt_dir: str, step: Optional[int] = None) -> list:
    """Rebuild `scheduler.Request`s from a snapshot (None-safe: returns []
    when no committed snapshot exists).  Requests with emitted tokens
    re-enter the engine on the recovery/replay path."""
    from repro.launch import scheduler  # here to avoid an import cycle

    meta, step = ckpt.load_meta(ckpt_dir, step=step)
    if meta is None:
        return []
    entries = meta["requests"]
    like = []
    for e in entries:
        leaf = {"prompt": np.zeros(0, np.int32),
                "tokens": np.zeros(0, np.int32)}
        if e["has_features"]:
            leaf["features"] = np.zeros(0, np.float32)
        if e.get("has_score_tokens"):
            leaf["score_tokens"] = np.zeros(0, np.int32)
        like.append(leaf)
    tree, _ = ckpt.restore_checkpoint(ckpt_dir, like, step=step)
    out = []
    for e, leaf in zip(entries, tree):
        req = scheduler.Request(
            rid=e["rid"], prompt=np.asarray(leaf["prompt"], np.int32),
            max_new_tokens=e["max_new_tokens"],
            arrival_time=e["arrival_time"],
            stop_tokens=e["stop_tokens"],
            features=np.asarray(leaf["features"], np.float32)
            if e["has_features"] else None,
            deadline=e["deadline"],
            method=e.get("method", "generate"),
            score_tokens=[int(t) for t in np.asarray(leaf["score_tokens"])]
            if e.get("has_score_tokens") else None,
            sampling=None if e.get("sampling") is None
            else scheduler.SamplingParams(**e["sampling"]))
        req.tokens = [int(t) for t in np.asarray(leaf["tokens"])]
        req.retries = e["retries"]
        out.append(req)
    return out
